"""Schema check over the committed benchmark artifacts.

Every ``results/bench_*.json`` must (a) parse, (b) be non-empty, and
(c) -- for the files whose consumers depend on specific top-level keys
(plots, CI acceptance gates, the roofline table) -- carry those keys.
The registry below is the contract: add an entry when a bench grows a
structured schema, so a refactor that silently drops ``acceptance`` or
``config`` fails CI instead of shipping an artifact the next reader
cannot parse.

    PYTHONPATH=src python -m benchmarks.check_results [results_dir]

Exit status 0 = all artifacts conform; 1 = violations (listed on stdout).
Also callable from tests: ``check(results_dir) -> list[str]``.
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List, Tuple

# required top-level keys per artifact family; files not listed here get
# the generic parse + non-empty check only.  The *_fast variants written
# by the CI smoke share their full run's schema.
REQUIRED: Dict[str, Tuple[str, ...]] = {
    "bench_chaos": ("config", "acceptance"),
    "bench_chaos_corr": ("config", "scale", "acceptance"),
    "bench_chaos_corr_fast": ("config", "scale", "acceptance"),
    "bench_chaos_fast": ("config", "acceptance"),
    "bench_head_fused": ("config", "rows", "acceptance"),
    "bench_head_fused_fast": ("config", "rows", "acceptance"),
    "bench_kernel_cost": ("config", "hlo", "roofline"),
    "bench_mobility": ("config", "acceptance"),
    "bench_ran": ("config", "acceptance"),
    "bench_scale": ("config", "ue_sweep", "acceptance"),
    "bench_scale_fast": ("config", "ue_sweep", "acceptance"),
    "bench_streaming": ("config", "acceptance"),
}


def check(results_dir: str) -> List[str]:
    errors: List[str] = []
    paths = sorted(glob.glob(os.path.join(results_dir, "bench_*.json")))
    if not paths:
        return [f"no bench_*.json artifacts under {results_dir!r}"]
    for path in paths:
        name = os.path.splitext(os.path.basename(path))[0]
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, ValueError) as e:
            errors.append(f"{name}: unparseable ({e})")
            continue
        if not payload:
            errors.append(f"{name}: empty artifact")
            continue
        if not isinstance(payload, (dict, list)):
            errors.append(f"{name}: top level must be an object or array, "
                          f"got {type(payload).__name__}")
            continue
        need = REQUIRED.get(name, ())
        if need and not isinstance(payload, dict):
            errors.append(f"{name}: registry expects an object with keys "
                          f"{need}, got {type(payload).__name__}")
            continue
        missing = [k for k in need if k not in payload]
        if missing:
            errors.append(f"{name}: missing required keys {missing} "
                          f"(has {sorted(payload)[:10]})")
    return errors


def main(argv: List[str] = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    results_dir = argv[0] if argv else os.path.join(
        os.path.dirname(__file__), os.pardir, "results")
    errs = check(results_dir)
    n = len(glob.glob(os.path.join(results_dir, "bench_*.json")))
    if errs:
        for e in errs:
            print(f"SCHEMA {e}")
        print(f"{len(errs)} violation(s) across {n} artifacts")
        return 1
    print(f"{n} bench artifacts conform")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Adaptive split selection (the paper's §III-C AF): mean E2E delay of the
adaptive controller vs every fixed split under a dynamic interference
trace.  The adaptive policy must track the best fixed policy."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, save
from repro.configs.swin_t_detection import CONFIG
from repro.core.adaptive import AdaptiveController, Objective
from repro.core.calibration import calibrate
from repro.core.channel import INTERFERENCE_LEVELS, dupf_path
from repro.core.compression import ActivationCodec
from repro.core.pipeline import SplitInferencePipeline
from repro.core.splitting import SwinSplitPlan, SERVER_ONLY, UE_ONLY
from repro.core.throughput import train_estimator


def run(n_frames: int = 150):
    system = calibrate()
    plan = SwinSplitPlan(CONFIG, params=None)
    rng = np.random.default_rng(7)
    trace = rng.choice(INTERFERENCE_LEVELS, size=n_frames).tolist()

    est = train_estimator(system.channel, "kpm+spec", n_train=2000, steps=300)
    prof = {UE_ONLY: 0.0, SERVER_ONLY: 1.0, "split1": 0.53, "split2": 0.42,
            "split3": 0.33, "split4": 0.27}

    def mean_delay(option, privacy_cap=1.0):
        ctrl = None
        if option is None:
            ctrl = AdaptiveController(
                system=system, estimator=est,
                objective=Objective(w_delay=1.0, w_energy=0.15,
                                    w_privacy=0.05, p_max=privacy_cap),
                path=dupf_path(), privacy_profile=prof)
        pipe = SplitInferencePipeline(plan=plan, system=system,
                                      codec=ActivationCodec(),
                                      controller=ctrl, execute_model=False,
                                      seed=13)
        logs = pipe.run_trace([None] * n_frames, trace, option)
        return (float(np.mean([l.delay_s for l in logs]) * 1e3),
                [l.option for l in logs])

    rows = {}
    for opt in plan.options:
        rows[opt], _ = mean_delay(opt)
    rows["adaptive"], choices = mean_delay(None)
    rows["adaptive_private(p<=0.6)"], _ = mean_delay(None, privacy_cap=0.6)
    for k, v in rows.items():
        print(f"  {k:24s} {v:8.1f} ms")
    switches = sum(a != b for a, b in zip(choices, choices[1:]))
    print(f"  adaptive switched split {switches}x over {n_frames} frames")
    save("bench_adaptive", rows)
    best_fixed = min(v for k, v in rows.items() if not k.startswith("adaptive"))
    rel = rows["adaptive"] / best_fixed
    return csv_line("adaptive_vs_fixed", 0,
                    f"adaptive_ms={rows['adaptive']:.0f};vs_best_fixed={rel:.3f}")


if __name__ == "__main__":
    print(run())

"""Paper Fig. 7: UE inference energy vs 5G TX energy per split (TX averaged
over interference levels).  Validates the paper's 25-50x gap claim and the
endpoint energies."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, save
from repro.configs.swin_t_detection import CONFIG
from repro.core.calibration import PAPER, calibrate
from repro.core.channel import INTERFERENCE_LEVELS
from repro.core.compression import ActivationCodec
from repro.core.pipeline import SplitInferencePipeline
from repro.core.splitting import SwinSplitPlan, SERVER_ONLY, UE_ONLY


def run(n_frames: int = 50):
    system = calibrate()
    plan = SwinSplitPlan(CONFIG, params=None)
    pipe = SplitInferencePipeline(plan=plan, system=system,
                                  codec=ActivationCodec(), controller=None,
                                  execute_model=False, seed=0)
    trace = list(INTERFERENCE_LEVELS) * (n_frames // len(INTERFERENCE_LEVELS))
    rows = []
    for opt in plan.options:
        logs = pipe.run_trace([None] * len(trace), trace, opt)
        e_inf = float(np.mean([l.energy_inf_j for l in logs]))
        e_tx = float(np.mean([l.energy_tx_j for l in logs]))
        rows.append({"split": opt, "inference_j": e_inf, "tx_j": e_tx,
                     "total_wh": (e_inf + e_tx) / 3600})
        ratio = e_inf / e_tx if e_tx > 0 else float("inf")
        print(f"  {opt:12s} inf={e_inf:7.2f} J tx={e_tx:6.3f} J "
              f"(inf/tx={ratio:5.1f}x) total={(e_inf+e_tx)/3600:.5f} Wh")
    save("bench_energy_breakdown", rows)
    ue = next(r for r in rows if r["split"] == UE_ONLY)["total_wh"]
    s1 = next(r for r in rows if r["split"] == "split1")["total_wh"]
    so = next(r for r in rows if r["split"] == SERVER_ONLY)["total_wh"]
    print(f"  UE-only {ue:.4f} Wh (paper {PAPER['ue_only_wh']}), split1 {s1:.4f} "
          f"(paper {PAPER['split1_wh']}), server {so:.5f} (paper {PAPER['server_only_wh']})")
    mid = [r for r in rows if r["split"].startswith("split")]
    ratios = [r["inference_j"] / max(r["tx_j"], 1e-9) for r in mid]
    return csv_line("fig7_energy_breakdown", 0,
                    f"ue_wh={ue:.4f};split1_wh={s1:.4f};min_inf_tx_ratio={min(ratios):.1f}")


if __name__ == "__main__":
    print(run())

"""Chaos / failure-injection sweep: outage severity -> recovery cost,
plus the dUPF-failover availability claim as a *scenario*.

Exercises the chaos subsystem (core/chaos.py) on the continuous-time
event engine:

  * **Zero-chaos anchor.**  A ChaosModel whose every spec is inert
    (heartbeats tick, nothing is scheduled) is asserted rng-paired
    BITWISE with the chaos-free engine -- the sweep's baseline IS
    today's engine, not a lookalike.

  * **Severity sweep.**  One edge-server outage opens at t0 = 5 s with
    the drop policy; its duration scales across the sweep.  Every frame
    arriving at the dead edge is lost, so time-to-recover, the longest
    per-UE dropped-frame burst and the loss count rise monotonically
    with outage duration while availability falls.

  * **Failover vs none.**  The same cell is run twice with identical
    seeds through one dUPF outage, once with mid-stream failover to the
    cUPF path and once without: every radio draw pairs, so the delta is
    the recovery policy alone.  Failover must yield strictly higher
    availability; the heartbeat detects the outage within one period of
    the timeout (detection is earned, not oracle); adaptive controllers
    re-converge after fail-back and the re-convergence cost is measured.

Acceptance anchors (asserted, persisted to results/bench_chaos.json):
  * inert chaos bitwise == the chaos-free engine,
  * time_to_recover and dropped-frame burst rise monotonically with
    outage duration; availability falls monotonically,
  * failover availability > no-failover availability, same seeds,
  * detection latency inside (timeout - period, timeout + period],
  * controller re-convergence after fail-back is measured (not None).

    PYTHONPATH=src python -m benchmarks.bench_chaos
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, save
from repro.configs.swin_t_detection import CONFIG
from repro.core.adaptive import (DEFAULT_PRIVACY_PROFILE, AdaptiveController,
                                 Objective)
from repro.core.calibration import calibrate
from repro.core.cell import CellSimulator
from repro.core.channel import cupf_path, dupf_path
from repro.core.chaos import ChaosConfig, ChaosModel, ChurnSpec, OutageSpec
from repro.core.throughput import ConstantRateEstimator

from repro.core.splitting import SwinSplitPlan

T0 = 5.0                      # every injected outage opens here
HB = dict(heartbeat_period_s=0.25, heartbeat_timeout_s=0.6)


def _sim(system, plan, chaos, *, n_ues, seed, budget_s, adaptive=False):
    ctrl = None
    if adaptive:
        ctrl = AdaptiveController(
            system=system, estimator=ConstantRateEstimator(50e6),
            objective=Objective(w_delay=1.0, w_energy=0.5, w_privacy=2.5),
            path=dupf_path(), privacy_profile=dict(DEFAULT_PRIVACY_PROFILE))
    return CellSimulator(plan=plan, system=system, n_ues=n_ues, seed=seed,
                         execute_model=False, frame_budget_s=budget_s,
                         controller=ctrl, chaos=chaos)


def run(fast: bool = False, option: str = "split3", level: float = -40.0,
        n_ues: int = 3, budget_s: float = 4.0, seed: int = 7):
    system = calibrate()
    plan = SwinSplitPlan(CONFIG, params=None)
    fps = 0.5
    n_frames = 24 if fast else 40
    durations = (2.0, 5.0, 10.0) if fast else (2.0, 5.0, 10.0, 20.0)
    trace = np.full((n_frames, n_ues), float(level))

    table = {"config": {"option": option, "level_db": level, "n_ues": n_ues,
                        "budget_s": budget_s, "n_frames": n_frames,
                        "fps": fps, "fast": fast, "t0_s": T0, **HB}}

    # -- zero-chaos anchor: inert chaos must BE the chaos-free engine --------
    base = _sim(system, plan, None, n_ues=n_ues, seed=seed,
                budget_s=budget_s).run_stream(trace, option=option, fps=fps)
    inert = ChaosModel(ChaosConfig(
        edge_outage=OutageSpec(), upf_outage=OutageSpec(),
        blackout=OutageSpec(), churn=ChurnSpec(), **HB))
    zero = _sim(system, plan, inert, n_ues=n_ues, seed=seed,
                budget_s=budget_s).run_stream(trace, option=option, fps=fps)
    paired = all(a == b for a, b in zip(base.logs, zero.logs)) \
        and len(base.logs) == len(zero.logs)

    # -- severity sweep: one edge outage, duration scales --------------------
    print(f"  {'outage':>7s} | {'ttr':>6s} {'burst':>5s} {'lost':>4s} "
          f"{'avail':>6s}")
    rows = []
    for dur in durations:
        chaos = ChaosModel(ChaosConfig(
            edge_outage=OutageSpec(schedule=((T0, dur),)),
            edge_policy="drop", **HB))
        res = _sim(system, plan, chaos, n_ues=n_ues, seed=seed,
                   budget_s=budget_s).run_stream(trace, option=option,
                                                 fps=fps)
        [m] = res.recovery
        row = {"outage_s": dur, "time_to_recover_s": m.time_to_recover_s,
               "burst_len": m.burst_len, "n_lost": m.n_lost,
               "detect_s": m.detect_s, "action": m.action,
               "availability": res.stats.availability}
        rows.append(row)
        table[f"outage{dur:g}"] = row
        print(f"  {dur:6.1f}s | {row['time_to_recover_s']:5.1f}s "
              f"{row['burst_len']:5d} {row['n_lost']:4d} "
              f"{row['availability']:6.3f}")

    # -- failover vs none: identical seeds, the policy is the only delta -----
    fo = {}
    for name, failover in (("failover", True), ("none", False)):
        chaos = ChaosModel(ChaosConfig(
            upf_outage=OutageSpec(schedule=((T0, 8.0),)),
            failover=failover, failover_path=cupf_path(), **HB))
        res = _sim(system, plan, chaos, n_ues=n_ues, seed=seed,
                   budget_s=budget_s, adaptive=True
                   ).run_stream(trace, option=None, fps=fps)
        [m] = res.recovery
        fo[name] = {"availability": res.stats.availability,
                    "n_lost_path": res.stats.n_lost_path,
                    "detect_s": m.detect_s,
                    "time_to_recover_s": m.time_to_recover_s,
                    "reconverge_frames": m.reconverge_frames}
    table["failover"] = fo
    print(f"  failover avail {fo['failover']['availability']:.3f} vs "
          f"none {fo['none']['availability']:.3f}; detect "
          f"{fo['failover']['detect_s']:.2f}s; reconverge "
          f"{fo['failover']['reconverge_frames']:.1f} frames")

    # -- acceptance anchors ---------------------------------------------------
    ttr = [r["time_to_recover_s"] for r in rows]
    burst = [r["burst_len"] for r in rows]
    avail = [r["availability"] for r in rows]
    ttr_ok = all(b > a for a, b in zip(ttr, ttr[1:]))
    burst_ok = (all(b >= a for a, b in zip(burst, burst[1:]))
                and burst[-1] > burst[0])
    avail_ok = all(b < a for a, b in zip(avail, avail[1:]))
    fo_ok = fo["failover"]["availability"] > fo["none"]["availability"]
    d = fo["failover"]["detect_s"] - T0
    detect_ok = (HB["heartbeat_timeout_s"] - HB["heartbeat_period_s"]
                 < d <= HB["heartbeat_timeout_s"] + HB["heartbeat_period_s"])
    reconv_ok = fo["failover"]["reconverge_frames"] is not None
    table["acceptance"] = {
        "zero_chaos_rng_paired_bitwise": bool(paired),
        "ttr_rises_with_outage": ttr_ok,
        "burst_rises_with_outage": burst_ok,
        "availability_falls_with_outage": avail_ok,
        "failover_beats_none": fo_ok,
        "detection_within_heartbeat_bounds": detect_ok,
        "reconvergence_measured": reconv_ok,
    }
    assert paired, \
        "inert chaos must replay the chaos-free engine bitwise"
    assert ttr_ok, f"time-to-recover must rise with outage duration: {ttr}"
    assert burst_ok, f"dropped-frame burst must rise with duration: {burst}"
    assert avail_ok, f"availability must fall with duration: {avail}"
    assert fo_ok, ("failover must beat no-failover availability under "
                   f"identical seeds: {fo}")
    assert detect_ok, f"detection latency {d:.2f}s outside heartbeat bounds"
    assert reconv_ok, "adaptive re-convergence must be measured"

    # fast mode gets its own results file (bench_compression convention):
    # the CI smoke must not clobber the committed full-run curves
    save("bench_chaos_fast" if fast else "bench_chaos", table)
    return csv_line(
        "chaos_recovery", 0,
        f"ttr={ttr[0]:.1f}->{ttr[-1]:.1f}s;burst={burst[0]}->{burst[-1]};"
        f"avail={avail[0]:.2f}->{avail[-1]:.2f};"
        f"failover={fo['failover']['availability']:.2f}>"
        f"none={fo['none']['availability']:.2f}")


if __name__ == "__main__":
    print(run())

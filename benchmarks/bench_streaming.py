"""Sustained-load streaming vs lock-step: backlog carry-over curves.

The continuous-time event engine (core/timeline.py) is the only engine
that can express *offered load*: per-UE frame clocks capture at ``fps``
while the shared cell drains at whatever the MAC sustains, so overload
accumulates -- uplink queues persist across frames, deadlines (anchored
at capture) slip further every frame, and the bounded in-flight window
starts skipping captures.  The lock-step engine run on the SAME cell and
trace re-anchors the clock every slot: its per-slot numbers are flat by
construction and identical for every offered load.

This bench sweeps fps over one RAN-scheduled cell (accounting mode,
fixed split) and reports, per load point: deadline-miss rate, drop rate,
mean frame age at detection, effective fps and mean E2E delay -- next to
the lock-step engine's (load-independent) numbers.

Acceptance anchors (asserted, persisted to results/bench_streaming.json):
  * deadline-miss and drop rate increase strictly with offered load,
  * the underloaded point matches the lock-step engine (no carry-over),
  * the lock-step engine reports the SAME per-slot numbers at every load
    (the slot barrier hides sustained-load dynamics).

    PYTHONPATH=src python -m benchmarks.bench_streaming
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, save
from repro.configs.swin_t_detection import CONFIG
from repro.core.calibration import calibrate
from repro.core.cell import CellSimulator
from repro.core.ran import RanCell, RanConfig, make_policy
from repro.core.splitting import SwinSplitPlan


def _mk(system, plan, n_ues, seed, tti_s, budget_s, policy="rr"):
    return CellSimulator(
        plan=plan, system=system, n_ues=n_ues, seed=seed,
        execute_model=False, frame_budget_s=budget_s,
        ran=RanCell(policy=make_policy(policy), cfg=RanConfig(tti_s=tti_s)))


def _stream_row(res, nominal_fps):
    done = res.completed_logs
    return {
        "offered_fps": nominal_fps,
        "deadline_miss_rate": res.deadline_miss_rate,
        "drop_rate": res.drop_rate,
        "mean_age_s": res.mean_age_s,
        "effective_fps": res.stats.effective_fps,
        "mean_delay_s": res.mean_delay_s,
        "max_age_s": float(max((l.age_s for l in done), default=0.0)),
        "edge_utilization": res.stats.edge_utilization,
    }


def run(fast: bool = False, option: str = "split2", level: float = -40.0,
        n_ues: int = 6, budget_s: float = 5.0, inflight: int = 3,
        seed: int = 7):
    system = calibrate()
    plan = SwinSplitPlan(CONFIG, params=None)
    fps_sweep = (0.2, 0.5, 0.8) if fast else (0.2, 0.35, 0.5, 0.8)
    n_frames = 6 if fast else 12
    tti_s = 0.005
    trace = np.full((n_frames, n_ues), float(level))

    table = {"config": {"option": option, "level_db": level, "n_ues": n_ues,
                        "budget_s": budget_s, "inflight": inflight,
                        "n_frames": n_frames, "tti_s": tti_s, "fast": fast}}

    # the lock-step engine has no fps knob: one run covers every load
    # point (same trace, same seed => same per-slot numbers regardless)
    lock = _mk(system, plan, n_ues, seed, tti_s, budget_s).run(
        trace, option=option)
    lock_by_slot = [float(np.mean([l.delay_s for l in lock.logs
                                   if l.frame_idx == t]))
                    for t in range(n_frames)]
    table["lockstep"] = {
        "deadline_miss_rate": lock.deadline_miss_rate,
        "mean_delay_s": lock.mean_delay_s,
        "delay_spread_s": float(max(lock_by_slot) - min(lock_by_slot)),
        "drop_rate": 0.0,      # the lock-step engine cannot drop at all
    }

    print(f"  lock-step: miss {lock.deadline_miss_rate:.2f}, delay "
          f"{lock.mean_delay_s:.2f}s (flat: per-slot spread "
          f"{table['lockstep']['delay_spread_s']:.3f}s) at EVERY load")
    print(f"  {'fps':>5s} | {'miss':>5s} {'drop':>5s} {'age':>7s} "
          f"{'eff_fps':>7s} {'delay':>7s} {'util':>5s}")
    rows = []
    for fps in fps_sweep:
        res = _mk(system, plan, n_ues, seed, tti_s, budget_s).run_stream(
            trace, option=option, fps=fps, inflight=inflight)
        row = _stream_row(res, fps)
        rows.append(row)
        table[f"fps{fps}"] = row
        print(f"  {fps:5.2f} | {row['deadline_miss_rate']:5.2f} "
              f"{row['drop_rate']:5.2f} {row['mean_age_s']:6.2f}s "
              f"{row['effective_fps']:7.2f} {row['mean_delay_s']:6.2f}s "
              f"{row['edge_utilization']:5.2f}")

    # -- acceptance anchors ---------------------------------------------------
    miss = [r["deadline_miss_rate"] for r in rows]
    drop = [r["drop_rate"] for r in rows]
    age = [r["mean_age_s"] for r in rows]
    miss_ok = all(b > a for a, b in zip(miss, miss[1:]))
    drop_ok = all(b >= a for a, b in zip(drop, drop[1:])) \
        and drop[-1] > drop[0]
    # the underloaded point carries nothing over: it matches lock-step
    calm_ok = abs(rows[0]["mean_delay_s"] - lock.mean_delay_s) \
        < 1e-6 * max(lock.mean_delay_s, 1.0)
    flat_ok = table["lockstep"]["delay_spread_s"] \
        < 0.2 * lock.mean_delay_s
    table["acceptance"] = {
        "miss_strictly_increases_with_load": miss_ok,
        "drop_increases_with_load": drop_ok,
        "underloaded_matches_lockstep": calm_ok,
        "lockstep_is_flat": flat_ok,
    }
    assert miss_ok, f"deadline-miss must rise strictly with load: {miss}"
    assert drop_ok, f"drop rate must rise with load: {drop}"
    assert calm_ok, "underloaded stream must reproduce the lock-step delay"
    assert flat_ok, "lock-step per-slot numbers should be flat (re-anchored)"
    assert all(b > a for a, b in zip(age, age[1:])), \
        f"frame age must grow with load: {age}"

    save("bench_streaming", table)
    return csv_line(
        "streaming_backlog", 0,
        f"miss={miss[0]:.2f}->{miss[-1]:.2f};"
        f"drop={drop[0]:.2f}->{drop[-1]:.2f};"
        f"age={age[0]:.2f}->{age[-1]:.2f}s;"
        f"lockstep_miss={lock.deadline_miss_rate:.2f}(flat)")


if __name__ == "__main__":
    print(run())

"""City-scale MAC benchmark: vectorized vs oracle engine, UE + device sweeps.

Two questions, answered with wall clocks on THIS host:

  1. **UE sweep** -- drain an identical synthetic streaming workload
     (fixed total offered bytes, so the TTI count stays comparable)
     through ``RanStream`` (python oracle) and ``VecRanStream`` (batched
     ``lax.scan``) at growing flow counts.  Compile time is excluded by
     a warmup drain per (size, policy); at small sizes the two engines'
     (flows drained, TTIs executed) are asserted equal, so the speedup
     compares genuinely identical schedules.  Beyond
     ``python_ceiling`` flows the oracle is extrapolated linearly in n
     from its largest measured per-TTI cost (marked as such in the
     JSON) -- running 20k+ python flows is pure waiting.

  2. **device sweep** -- subprocess per point with
     ``--xla_force_host_platform_device_count=N``: ``MultiCellVecMac``
     over an 8-cell city with the cell axis on ``make_host_mesh()``
     via ``cell_axis_sharding``.  Asserted: per-slot time grows
     SUB-LINEARLY in forced device count (the scan is elementwise
     across cells, so partitioning adds no collectives).  On this
     single-core container the virtual devices share one core, so the
     expected curve is flat-ish, not falling; the JSON records
     ``host_cpus`` so readers can judge the numbers in context.

Honest framing of the ISSUE's >=100x target: the acceptance floor
asserted here is the ROBUST one (>=20x at the 10k headline on a single
CPU core, where the oracle's ~2 us/flow/TTI python loop races F-wide
memory-bound XLA elementwise ops).  The measured numbers and whether
the 100x target was met on this host are both recorded in the JSON;
DESIGN.md section 10 explains why the residual gap is
bandwidth/parallelism, not dispatch overhead.

    PYTHONPATH=src python -m benchmarks.bench_scale          # full sweep
    PYTHONPATH=src python -m benchmarks.bench_scale --fast   # CI smoke
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import csv_line, save

TOTAL_BYTES = 2_625_000     # fixed offered load => TTI count ~ constant in n
SPEEDUP_FLOOR_FULL = 20.0   # robust single-core floor at the 10k headline
SPEEDUP_FLOOR_FAST = 2.0    # 1k flows barely amortizes kernel dispatch
TARGET_SPEEDUP = 100.0      # the ISSUE target (needs parallel backends)


def _build(n, pol, vec, seed=5):
    from repro.core.engine_vec import synthetic_flows
    from repro.core.ran import (RanCell, RanConfig, RanStream, UplinkRequest,
                                make_policy)
    from repro.core.ran_vec import VecRanStream
    cell = RanCell(policy=make_policy(pol), cfg=RanConfig(tti_s=1e-3))
    strm = VecRanStream(cell, n) if vec else RanStream(cell)
    w = synthetic_flows(n, seed, mean_bytes=max(64, TOTAL_BYTES // n))
    for i in range(n):
        strm.enqueue(UplinkRequest(
            ue_id=int(w["ue"][i]), n_bytes=int(w["n_bytes"][i]),
            enqueue_s=float(w["enq"][i]), deadline_s=float(w["dead"][i]),
            link_rate_bps=float(w["link_rate_bps"][i])), int(w["cohort"][i]))
    return strm


def _drain(strm, seed=5):
    rng = np.random.default_rng(seed)
    t0 = time.perf_counter()
    flows = strm.advance(np.inf, rng)
    return time.perf_counter() - t0, len(flows), strm._k


def _ue_sweep(sizes, policies, python_ceiling, repeats=1):
    rows = []
    for n in sizes:
        for pol in policies:
            _drain(_build(n, pol, vec=True))          # warmup: compile
            # min over repeats: wall clocks on a shared single-core host
            # see transient contention; the minimum is the honest
            # engine cost, the excess is the neighbor's
            tv, nf_v, k_v = min(
                (_drain(_build(n, pol, vec=True)) for _ in range(repeats)),
                key=lambda r: r[0])
            row = {"n_flows": n, "policy": pol, "ttis": k_v,
                   "vec_s": tv, "vec_us_per_tti": tv / k_v * 1e6}
            if n <= python_ceiling:
                tp, nf_p, k_p = min(
                    (_drain(_build(n, pol, vec=False))
                     for _ in range(repeats)), key=lambda r: r[0])
                assert (nf_v, k_v) == (nf_p, k_p), \
                    (pol, n, "engines diverged", (nf_v, k_v), (nf_p, k_p))
                row.update(py_s=tp, py_us_per_tti=tp / k_p * 1e6,
                           python_extrapolated=False)
            else:  # linear-in-n extrapolation from the largest measured pt
                base = max((r for r in rows
                            if r["policy"] == pol
                            and not r["python_extrapolated"]),
                           key=lambda r: r["n_flows"])
                us = base["py_us_per_tti"] * n / base["n_flows"]
                row.update(py_s=us * 1e-6 * k_v, py_us_per_tti=us,
                           python_extrapolated=True)
            row["speedup"] = row["py_s"] / row["vec_s"]
            rows.append(row)
            tag = "~" if row["python_extrapolated"] else " "
            print(f"  {pol} n={n:6d}: ttis={k_v:5d} "
                  f"py={row['py_s'] * 1e3:9.1f}ms{tag} "
                  f"vec={tv * 1e3:8.1f}ms speedup={row['speedup']:6.1f}x{tag} "
                  f"({row['vec_us_per_tti']:6.0f} us/tti vec)")
    return rows


def _traced_overhead(n, pol, repeats=3):
    """Traced vs untraced 10k-flow vectorized drain: telemetry rides the
    vectorized engine as ONE post-drain numpy pass (mac_flows_bulk), so
    the traced wall time must stay within 1.25x of the untraced drain --
    the tentpole's 'does not kill the 30x speedup' acceptance bar."""
    from repro.core.telemetry import Telemetry

    _drain(_build(n, pol, vec=True))                  # warmup: compile
    untraced = min(_drain(_build(n, pol, vec=True))[0]
                   for _ in range(repeats))

    def traced_once():
        strm = _build(n, pol, vec=True)
        rng = np.random.default_rng(5)
        tele = Telemetry()
        tele.begin_run("stream/vectorized", "absolute", n)
        t0 = time.perf_counter()
        flows = strm.advance(np.inf, rng)
        tele.mac_flows_bulk(0, flows, strm.cfg.tti_s, strm.cfg.n_prbs)
        dt = time.perf_counter() - t0
        assert len(tele.spans) == len(flows)
        return dt

    traced = min(traced_once() for _ in range(repeats))
    ratio = traced / untraced
    print(f"  traced overhead n={n}: untraced={untraced * 1e3:.1f}ms "
          f"traced={traced * 1e3:.1f}ms ratio={ratio:.3f}x")
    return {"n_flows": n, "policy": pol, "untraced_s": untraced,
            "traced_s": traced, "ratio": ratio}


def _device_sweep(device_counts, n_ues, n_cells):
    """One subprocess per point: the forced-device flag must be set
    before jax initializes, so each count needs a fresh interpreter."""
    rows = []
    for nd in device_counts:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={nd} "
                            + env.get("XLA_FLAGS", "")).strip()
        env.setdefault("JAX_PLATFORMS", "cpu")
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_scale",
             "--device-worker", str(nd), str(n_ues), str(n_cells)],
            env=env, capture_output=True, text=True, timeout=600)
        if out.returncode != 0:
            raise RuntimeError(f"device worker ({nd}) failed:\n{out.stderr}")
        row = json.loads(out.stdout.strip().splitlines()[-1])
        rows.append(row)
        print(f"  devices={row['n_devices']}: "
              f"{row['s_per_slot'] * 1e3:7.1f} ms/slot "
              f"({n_ues} UEs / {n_cells} cells)")
    return rows


def _device_worker(n_dev, n_ues, n_cells):
    """Child-process body: jax initialized AFTER XLA_FLAGS took effect."""
    import jax
    from repro.core.engine_vec import MultiCellVecMac, synthetic_city
    from repro.core.ran import MultiCell, RanCell, RanConfig, make_policy
    from repro.launch.mesh import make_host_mesh
    assert len(jax.devices()) == n_dev, \
        (len(jax.devices()), n_dev, "forced device count did not take")
    cells = [RanCell(policy=make_policy("edf"), cfg=RanConfig(tti_s=1e-3))
             for _ in range(n_cells)]
    mac = MultiCellVecMac(MultiCell(cells), mesh=make_host_mesh())
    batches = synthetic_city(n_ues, n_cells, seed=3)
    rngs = [np.random.default_rng(k)
            for k in np.random.SeedSequence(1).spawn(n_cells)]
    mac.serve_slot_arrays(batches, rngs)                  # warmup: compile
    n_slots = 3
    t0 = time.perf_counter()
    for _ in range(n_slots):
        mac.serve_slot_arrays(batches, rngs)
    dt = (time.perf_counter() - t0) / n_slots
    print(json.dumps({"n_devices": n_dev, "n_ues": n_ues,
                      "n_cells": n_cells, "s_per_slot": dt}))


def run(fast: bool = False):
    if fast:
        sizes, python_ceiling = (256, 1024), 1024
        policies = ("edf",)
        headline, floor = 1024, SPEEDUP_FLOOR_FAST
        device_counts, city_ues, city_cells = (1, 2), 512, 4
    else:
        sizes = (64, 256, 1024, 4096, 10240, 20480, 50000)
        python_ceiling = 10240
        policies = ("rr", "pf", "edf")
        headline, floor = 10240, SPEEDUP_FLOOR_FULL
        device_counts, city_ues, city_cells = (1, 2, 4), 4096, 8

    table = {"config": {
        "fast": fast, "sizes": list(sizes), "policies": list(policies),
        "headline_flows": headline, "python_ceiling": python_ceiling,
        "total_bytes": TOTAL_BYTES, "device_counts": list(device_counts),
        "city_ues": city_ues, "city_cells": city_cells,
        "host_cpus": os.cpu_count(),
        "timing": "min over repeats (3 full / 1 fast), warmup excluded",
    }}

    print(f"  -- UE sweep ({'fast' if fast else 'full'}) --")
    ue_rows = _ue_sweep(sizes, policies, python_ceiling,
                        repeats=1 if fast else 3)
    table["ue_sweep"] = ue_rows

    print("  -- device sweep --")
    dev_rows = _device_sweep(device_counts, city_ues, city_cells)
    table["device_sweep"] = dev_rows

    # telemetry cost at the 10k headline (both modes: the bound is the
    # tentpole's acceptance bar, so the CI smoke must enforce it too)
    print("  -- traced overhead --")
    tr = _traced_overhead(10240, policies[-1])
    table["traced_overhead"] = tr

    # -- acceptance -----------------------------------------------------------
    head = {r["policy"]: r for r in ue_rows if r["n_flows"] == headline}
    small = {r["policy"]: r for r in ue_rows if r["n_flows"] == sizes[0]}
    floor_ok = all(r["speedup"] >= floor for r in head.values())
    grows_ok = all(head[p]["speedup"] > small[p]["speedup"]
                   for p in head)
    t1 = dev_rows[0]["s_per_slot"]
    sublinear_ok = all(r["s_per_slot"] < r["n_devices"] * t1
                       for r in dev_rows[1:])
    target_met = all(r["speedup"] >= TARGET_SPEEDUP for r in head.values())
    table["acceptance"] = {
        "speedup_floor": floor,
        "headline_speedup_above_floor": floor_ok,
        "speedup_grows_with_scale": grows_ok,
        "device_scaling_sublinear": sublinear_ok,
        "target_100x_met": target_met,
        "traced_overhead_bound": 1.25,
        "traced_overhead_ok": tr["ratio"] <= 1.25,
        "target_100x_context": (
            "measured on a single CPU core: the oracle's python loop and "
            "the XLA kernels contend for the same core, so the ceiling is "
            "the F-wide memory-bound elementwise work (~0.6 ms/TTI at "
            "10k flows); the 100x target assumes the vectorized path gets "
            "a parallel backend (multi-core / accelerator) while the "
            "oracle stays a single python thread"),
    }
    assert floor_ok, \
        {p: round(r["speedup"], 1) for p, r in head.items()}
    assert grows_ok, "speedup must grow from the smallest to headline size"
    assert sublinear_ok, \
        [(r["n_devices"], r["s_per_slot"]) for r in dev_rows]
    assert tr["ratio"] <= 1.25, \
        f"tracing cost {tr['ratio']:.3f}x exceeds the 1.25x bound"

    save("bench_scale_fast" if fast else "bench_scale", table)
    sp = {p: head[p]["speedup"] for p in sorted(head)}
    return csv_line(
        "city_scale", head[policies[-1]]["vec_us_per_tti"],
        ";".join(f"{p}={v:.1f}x@{headline}" for p, v in sp.items())
        + f";target100x={'met' if target_met else 'unmet_single_core'}")


def main() -> int:
    if "--device-worker" in sys.argv:
        i = sys.argv.index("--device-worker")
        _device_worker(int(sys.argv[i + 1]), int(sys.argv[i + 2]),
                       int(sys.argv[i + 3]))
        return 0
    print(run(fast="--fast" in sys.argv))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Benchmark harness: one module per paper figure + the roofline table.

    PYTHONPATH=src python -m benchmarks.run [--only substr]

Prints one ``name,us_per_call,derived`` CSV line per bench (collected at
the end) and writes detailed rows to results/*.json.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true",
                    help="smoke mode (CI): the registry below already runs "
                         "every bench in its reduced/fast variant; this flag "
                         "exists so automation can state the intent "
                         "explicitly and future slow registrations must "
                         "respect it")
    args = ap.parse_args()

    from benchmarks import (bench_adaptive, bench_cell, bench_chaos,
                            bench_chaos_corr, bench_compression, bench_dupf,
                            bench_e2e_delay, bench_energy_breakdown,
                            bench_energy_privacy, bench_estimator,
                            bench_kernel_cost, bench_mobility, bench_ran,
                            bench_scale, bench_streaming, bench_tx_energy)

    benches = [
        # fast mode: reduced model, same legacy-vs-fused comparison + the
        # bit-identity assert (the full-size run is the module's __main__)
        ("fig3_compression", lambda: bench_compression.run(fast=True)),
        ("fig4_e2e_delay", bench_e2e_delay.run),
        ("fig5_energy_privacy", bench_energy_privacy.run),
        ("fig6_tx_energy", bench_tx_energy.run),
        ("fig7_energy_breakdown", bench_energy_breakdown.run),
        ("fig8_dupf", bench_dupf.run),
        ("estimator_ablation", bench_estimator.run),
        ("adaptive_vs_fixed", bench_adaptive.run),
        ("cell_batching", bench_cell.run),
        # fast mode: smaller load sweep + coarser TTI, same acceptance
        # anchors (idle-cell calibration, load degradation, EDF vs RR)
        ("ran_scheduler", lambda: bench_ran.run(fast=True)),
        # fast mode: shorter trace + coarser fps sweep, same acceptance
        # anchors (miss/drop strictly rise with load, lock-step flat)
        ("streaming_backlog", lambda: bench_streaming.run(fast=True)),
        # fast mode: shorter trace + coarser speed sweep, same acceptance
        # anchors (static point bitwise == today's engine, miss/age rise
        # with speed, dUPF beats cUPF mean+std under identical seeds)
        ("mobility_handover", lambda: bench_mobility.run(fast=True)),
        # fast mode: ~1k flows + 2 forced devices, same acceptance
        # anchors (oracle schedule identical, speedup floor, sub-linear
        # device scaling); the full 64 -> 50k sweep is the module's
        # __main__ and commits results/bench_scale.json
        ("city_scale", lambda: bench_scale.run(fast=True)),
        # fast mode: shorter trace + coarser severity sweep, same
        # acceptance anchors (inert chaos bitwise == today's engine,
        # recovery cost rises with outage duration, failover beats
        # no-failover); writes bench_chaos_fast.json so the CI smoke
        # never clobbers the committed full-run curves
        ("chaos_recovery", lambda: bench_chaos.run(fast=True)),
        # fast mode: 1k-flow drain instead of 10k, same acceptance
        # anchors (correlated site faults strictly worse than staggered
        # faults of equal marginal rate, vectorized engine field-exact
        # on the correlated run -- the CI vectorized-chaos smoke --
        # batched park/adopt drain <= 1.5x chaos-free); writes
        # bench_chaos_corr_fast.json, never the committed full curves
        ("chaos_correlated", lambda: bench_chaos_corr.run(fast=True)),
        # compiles the reduced Swin forward and pushes it through the
        # loop-aware HLO analyzer (launch/hlo_cost.py) + roofline table
        # (benchmarks/roofline.py) -- the dry-run-free path, so the CI
        # smoke exercises both formerly write-only modules and commits
        # results/bench_kernel_cost.json
        ("kernel_cost", lambda: bench_kernel_cost.run(fast=True)),
        # fused Swin head (one device call for head + int8 quant epilogue,
        # DESIGN.md §13) vs the eager-XLA + separate-quant baseline:
        # asserts payload byte-identity and the 2x speedup floor; the
        # all-splits full run is the module's __main__ and commits
        # results/bench_head_fused.json
        ("head_fused", lambda: bench_kernel_cost.run_head_fused(fast=True)),
    ]
    if args.only:
        benches = [(n, f) for n, f in benches if args.only in n]

    lines = []
    failed = 0
    for name, fn in benches:
        print(f"== {name} ==", flush=True)
        t0 = time.perf_counter()
        try:
            line = fn()
            dt = time.perf_counter() - t0
            print(f"   ({dt:.1f}s)\n")
            lines.append(line)
        except Exception:
            failed += 1
            traceback.print_exc()
            lines.append(f"{name},0,FAILED")

    # roofline summary (reads the dry-run artifact if present)
    try:
        import os
        from benchmarks.roofline import load, table
        art = ("results/dryrun_optimized.json"
               if os.path.exists("results/dryrun_optimized.json")
               else "results/dryrun_baseline.json")
        cells = load(art)
        rows = [r for r in table(cells) if r["status"] == "OK"]
        worst = min(rows, key=lambda r: r["roofline_frac"])
        best = max(rows, key=lambda r: r["roofline_frac"])
        lines.append(f"roofline,0,cells={len(rows)};best={best['arch']}/"
                     f"{best['shape']}={100*best['roofline_frac']:.1f}%;"
                     f"worst={worst['arch']}/{worst['shape']}="
                     f"{100*worst['roofline_frac']:.2f}%")
    except Exception:
        lines.append("roofline,0,missing_dryrun_artifact")

    print("name,us_per_call,derived")
    for l in lines:
        print(l)
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())

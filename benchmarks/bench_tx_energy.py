"""Paper Fig. 6: UE 5G-transmission energy per frame vs interference, per
split point (radio effort rises with jamming)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, save
from repro.configs.swin_t_detection import CONFIG
from repro.core.calibration import calibrate
from repro.core.channel import INTERFERENCE_LEVELS
from repro.core.compression import ActivationCodec
from repro.core.pipeline import SplitInferencePipeline
from repro.core.splitting import SwinSplitPlan, SERVER_ONLY, UE_ONLY


def run(n_frames: int = 30):
    system = calibrate()
    plan = SwinSplitPlan(CONFIG, params=None)
    pipe = SplitInferencePipeline(plan=plan, system=system,
                                  codec=ActivationCodec(), controller=None,
                                  execute_model=False, seed=0)
    table = {}
    for opt in plan.options:
        if opt == UE_ONLY:
            continue
        table[opt] = {}
        for lvl in INTERFERENCE_LEVELS:
            logs = pipe.run_trace([None] * n_frames, [lvl] * n_frames, opt)
            table[opt][lvl] = float(np.mean([l.energy_tx_j for l in logs]))
    save("bench_tx_energy", table)
    print(f"  {'option':12s} " + " ".join(f"{l:>8d}dB" for l in INTERFERENCE_LEVELS))
    for opt, row in table.items():
        print(f"  {opt:12s} " + " ".join(f"{row[l]*1e3:7.1f}mJ" for l in INTERFERENCE_LEVELS))
    rising = all(
        table[o][-5] > table[o][-40] for o in table)
    print(f"  TX energy rises with interference for every split: {rising}")
    return csv_line("fig6_tx_energy", 0, f"rising_with_interference={rising}")


if __name__ == "__main__":
    print(run())

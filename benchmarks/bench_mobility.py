"""Mobility sweep: UE speed x handover rate -> deadline miss, frame age,
energy; plus the dUPF-vs-cUPF user-plane claim as a *scenario*.

Every pre-mobility engine drew each UE from a stationary fading
distribution inside one eternal cell.  This bench exercises the mobility
subsystem (core/mobility.py) on the continuous-time event engine:

  * **Speed sweep.**  UEs shuttle between an AI-RAN site (dUPF local
    breakout) and a macro site (cUPF backhaul) 400 m apart on scripted
    ping-pong trajectories.  Faster UEs cross the A3 boundary more often
    -- more handovers, each costing a path-relocation gap, a flushed
    in-flight HARQ transport block and a granted-rate estimator reset --
    so deadline-miss rate and mean frame age rise monotonically with
    speed.  The static point (speed 0: parked at the reference distance)
    is asserted rng-paired BITWISE with the mobility-free engine -- the
    sweep's baseline IS today's engine, not a lookalike.

  * **dUPF vs cUPF.**  The same mobile cell is run twice with identical
    seeds, once with the serving site's user plane at the dUPF and once
    hauling to the central UPF: every radio draw pairs, so the delta is
    the path alone.  The dUPF serving path must yield lower mean AND
    lower std user-plane delay (the paper's jitter claim, Fig. 8).

Acceptance anchors (asserted, persisted to results/bench_mobility.json):
  * static point bitwise == the mobility-free engine,
  * miss rate and mean age rise monotonically with UE speed,
  * handover count rises with UE speed,
  * dUPF < cUPF in both mean and std of user-plane delay, same seeds.

    PYTHONPATH=src python -m benchmarks.bench_mobility
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, save
from repro.configs.swin_t_detection import CONFIG
from repro.core.calibration import calibrate
from repro.core.cell import CellSimulator
from repro.core.channel import cupf_path, dupf_path
from repro.core.mobility import (CellSite, MobilityConfig, MobilityModel,
                                 WaypointTrajectory, static_mobility,
                                 two_cell_sites)
from repro.core.ran import MultiCell, RanCell, RanConfig, make_policy
from repro.core.splitting import SwinSplitPlan

PAIRED_FIELDS = ("delay_s", "tx_s", "path_s", "rate_bps", "energy_inf_j",
                 "energy_tx_j", "air_s", "prb_share")


def _cells(n, tti_s):
    return MultiCell([RanCell(policy=make_policy("edf"),
                              cfg=RanConfig(tti_s=tti_s))
                      for _ in range(n)])


def _sim(system, plan, n_ues, seed, tti_s, budget_s, *, ran, mobility):
    return CellSimulator(plan=plan, system=system, n_ues=n_ues, seed=seed,
                         execute_model=False, frame_budget_s=budget_s,
                         ran=ran, mobility=mobility)


def _row(res, speed):
    done = res.completed_logs
    return {
        "speed_mps": speed,
        "deadline_miss_rate": res.deadline_miss_rate,
        "mean_age_s": res.mean_age_s,
        "mean_delay_s": res.mean_delay_s,
        "n_handovers": res.stats.n_handovers,
        "mean_ue_energy_j": (float(np.mean(res.ue_wall_energy_j))
                             if res.ue_wall_energy_j else 0.0),
        "mean_path_s": float(np.mean([l.path_s for l in done
                                      if l.path_s > 0] or [0.0])),
    }


def run(fast: bool = False, option: str = "split3", level: float = -40.0,
        n_ues: int = 4, budget_s: float = 4.0, seed: int = 7):
    system = calibrate()
    plan = SwinSplitPlan(CONFIG, params=None)
    tti_s = 0.005
    fps = 0.5
    n_frames = 10 if fast else 20
    speeds = (0.0, 5.0, 10.0, 20.0) if fast else (0.0, 2.0, 5.0, 10.0, 20.0)
    trace = np.full((n_frames, n_ues), float(level))
    sites = two_cell_sites(400.0)
    mcfg = MobilityConfig(a3_ttt_s=2.0, relocation_gap_s=0.3)

    table = {"config": {"option": option, "level_db": level, "n_ues": n_ues,
                        "budget_s": budget_s, "n_frames": n_frames,
                        "fps": fps, "tti_s": tti_s, "fast": fast,
                        "site_spacing_m": 400.0}}

    # -- static anchor: speed 0 must BE the mobility-free engine -------------
    base = _sim(system, plan, n_ues, seed, tti_s, budget_s,
                ran=RanCell(policy=make_policy("edf"),
                            cfg=RanConfig(tti_s=tti_s)),
                mobility=None).run_stream(trace, option=option, fps=fps)

    print(f"  {'speed':>6s} | {'miss':>5s} {'age':>7s} {'delay':>7s} "
          f"{'HOs':>4s} {'energy':>8s}")
    rows = []
    static_paired = None
    for speed in speeds:
        if speed == 0.0:
            mob = static_mobility(n_ues, site=sites[0], cfg=mcfg)
        else:
            traj = [WaypointTrajectory(((30.0, 0.0), (370.0, 0.0)),
                                       speed_mps=speed, loop=True)
                    for _ in range(n_ues)]
            mob = MobilityModel(sites, traj, mcfg)
        res = _sim(system, plan, n_ues, seed, tti_s, budget_s,
                   ran=_cells(len(sites), tti_s) if speed else
                   RanCell(policy=make_policy("edf"),
                           cfg=RanConfig(tti_s=tti_s)),
                   mobility=mob).run_stream(trace, option=option, fps=fps)
        if speed == 0.0:
            static_paired = all(
                getattr(a, f) == getattr(b, f)
                for a, b in zip(base.logs, res.logs)
                for f in PAIRED_FIELDS)
        row = _row(res, speed)
        rows.append(row)
        table[f"speed{speed:g}"] = row
        print(f"  {speed:6.1f} | {row['deadline_miss_rate']:5.2f} "
              f"{row['mean_age_s']:6.2f}s {row['mean_delay_s']:6.2f}s "
              f"{row['n_handovers']:4d} {row['mean_ue_energy_j']:7.1f}J")

    # -- dUPF vs cUPF: identical seeds, the path is the only delta -----------
    upf = {}
    for name, path in (("dupf", dupf_path()), ("cupf", cupf_path())):
        site = CellSite(0.0, 0.0, path, name=name)
        traj = [WaypointTrajectory(((30.0, 0.0), (150.0, 0.0)),
                                   speed_mps=5.0, loop=True)
                for _ in range(n_ues)]
        res = _sim(system, plan, n_ues, seed, tti_s, budget_s,
                   ran=RanCell(policy=make_policy("edf"),
                               cfg=RanConfig(tti_s=tti_s)),
                   mobility=MobilityModel([site], traj, mcfg)
                   ).run_stream(trace, option=option, fps=fps)
        ps = [l.path_s for l in res.completed_logs if l.path_s > 0]
        upf[name] = {"mean_path_s": float(np.mean(ps)),
                     "std_path_s": float(np.std(ps)),
                     "mean_delay_s": res.mean_delay_s}
    table["upf"] = upf
    print(f"  dUPF path {upf['dupf']['mean_path_s'] * 1e3:6.1f} ms "
          f"(std {upf['dupf']['std_path_s'] * 1e3:5.1f}) vs cUPF "
          f"{upf['cupf']['mean_path_s'] * 1e3:6.1f} ms "
          f"(std {upf['cupf']['std_path_s'] * 1e3:5.1f}), same seeds")

    # -- acceptance anchors ---------------------------------------------------
    miss = [r["deadline_miss_rate"] for r in rows]
    age = [r["mean_age_s"] for r in rows]
    hos = [r["n_handovers"] for r in rows]
    miss_ok = all(b > a for a, b in zip(miss, miss[1:]))
    age_ok = all(b > a for a, b in zip(age, age[1:]))
    ho_ok = (hos[0] == 0                       # static UEs never hand over
             and all(b >= a for a, b in zip(hos, hos[1:]))
             and hos[-1] > 0)                  # the fastest sweep point does
    upf_ok = (upf["dupf"]["mean_path_s"] < upf["cupf"]["mean_path_s"]
              and upf["dupf"]["std_path_s"] < upf["cupf"]["std_path_s"])
    table["acceptance"] = {
        "static_point_rng_paired_bitwise": bool(static_paired),
        "miss_rises_with_speed": miss_ok,
        "age_rises_with_speed": age_ok,
        "handovers_rise_with_speed": ho_ok,
        "dupf_beats_cupf_mean_and_std": upf_ok,
    }
    assert static_paired, \
        "speed-0 mobility must replay the mobility-free engine bitwise"
    assert miss_ok, f"deadline-miss must rise strictly with speed: {miss}"
    assert age_ok, f"frame age must rise strictly with speed: {age}"
    assert ho_ok, f"handover count must rise with speed: {hos}"
    assert upf_ok, ("dUPF must beat cUPF in mean and std user-plane delay "
                    f"under identical seeds: {upf}")

    # fast mode gets its own results file (bench_compression convention):
    # the CI smoke must not clobber the committed full-run curves
    save("bench_mobility_fast" if fast else "bench_mobility", table)
    return csv_line(
        "mobility_handover", 0,
        f"miss={miss[0]:.2f}->{miss[-1]:.2f};age={age[0]:.2f}->"
        f"{age[-1]:.2f}s;hos={hos[0]}->{hos[-1]};"
        f"dupf_path={upf['dupf']['mean_path_s'] * 1e3:.0f}ms<"
        f"cupf={upf['cupf']['mean_path_s'] * 1e3:.0f}ms")


if __name__ == "__main__":
    print(run())

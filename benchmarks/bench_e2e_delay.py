"""Paper Fig. 4: E2E delay per execution option x interference level.

Accounting-mode pipeline (full-size calibrated system, 40 frames per
point).  Split-1 / UE-only / server-only are validated against the paper's
published numbers; the other splits and the -5 dB crossover are the
simulator's predictions.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, save
from repro.configs.swin_t_detection import CONFIG
from repro.core.calibration import PAPER, calibrate
from repro.core.channel import INTERFERENCE_LEVELS
from repro.core.compression import ActivationCodec
from repro.core.pipeline import SplitInferencePipeline
from repro.core.splitting import SwinSplitPlan, SERVER_ONLY, UE_ONLY


def run(n_frames: int = 40):
    system = calibrate()
    plan = SwinSplitPlan(CONFIG, params=None)
    pipe = SplitInferencePipeline(plan=plan, system=system,
                                  codec=ActivationCodec(), controller=None,
                                  execute_model=False, seed=0)
    table = {}
    for opt in plan.options:
        table[opt] = {}
        for lvl in INTERFERENCE_LEVELS:
            logs = pipe.run_trace([None] * n_frames, [lvl] * n_frames, opt)
            table[opt][lvl] = float(np.mean([l.delay_s for l in logs]) * 1e3)
    save("bench_e2e_delay", table)

    print(f"  {'option':12s} " + " ".join(f"{l:>9d}dB" for l in INTERFERENCE_LEVELS))
    for opt, row in table.items():
        print(f"  {opt:12s} " + " ".join(f"{row[l]:9.0f}ms" for l in INTERFERENCE_LEVELS))

    # validation vs paper
    errs = []
    errs.append(abs(table[UE_ONLY][-30] - PAPER["ue_only_ms"]) / PAPER["ue_only_ms"])
    errs.append(abs(table[SERVER_ONLY][-40] - PAPER["server_only_ms"]) / PAPER["server_only_ms"])
    for lvl, want in PAPER["split1_ms"].items():
        errs.append(abs(table["split1"][lvl] - want) / want)
    crossover = table["split4"][-5] > table[UE_ONLY][-5]
    print(f"  validation: max rel err vs paper anchors = {max(errs):.3f}; "
          f"-5dB split4>UE crossover reproduced = {crossover}")
    return csv_line("fig4_e2e_delay", 0,
                    f"max_rel_err={max(errs):.3f};crossover={crossover}")


if __name__ == "__main__":
    print(run())

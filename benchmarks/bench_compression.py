"""Paper Fig. 3: intermediate payload size, raw vs compressed, per split.

Runs the REAL full-size Swin-T head on a realistic video frame and the
real codec.  Reports the paper-faithful pipeline (INT8+zlib) and the
beyond-paper delta-filtered variant side by side (§Perf-codec).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_line, save
from repro.configs.swin_t_detection import CONFIG
from repro.core.compression import ActivationCodec
from repro.core.splitting import SwinSplitPlan, SERVER_ONLY, UE_ONLY
from repro.data.video import SyntheticVideo, VideoConfig
from repro.models import swin as SW


def run(fast: bool = False):
    cfg = CONFIG
    params = SW.init(cfg, jax.random.PRNGKey(0))
    video = SyntheticVideo(VideoConfig(h=cfg.img_h, w=cfg.img_w, seed=0))
    img = jnp.asarray(video.frame(0)[0])[None]
    plan = SwinSplitPlan(cfg, params)
    paper = ActivationCodec(mode="int8_zlib")
    delta = ActivationCodec(mode="int8_delta_zlib")

    rows = []
    input_mb = cfg.img_h * cfg.img_w * 3 / 2 ** 20
    for opt in plan.options:
        if opt in (UE_ONLY, SERVER_ONLY):
            continue
        payload, _ = plan.head(img, opt)
        t0 = time.perf_counter()
        cp = paper.compress(payload)
        t_paper = time.perf_counter() - t0
        t0 = time.perf_counter()
        cd = delta.compress(payload)
        t_delta = time.perf_counter() - t0
        rows.append({
            "split": opt,
            "raw_mb": cp.raw_bytes / 2 ** 20,
            "int8_zlib_mb": cp.compressed_bytes / 2 ** 20,
            "int8_zlib_reduction": 1 - cp.ratio,
            "int8_zlib_s": t_paper,
            "delta_mb": cd.compressed_bytes / 2 ** 20,
            "delta_reduction": 1 - cd.ratio,
            "delta_s": t_delta,
            "x_input": cp.raw_bytes / 2 ** 20 / input_mb,
        })
    save("bench_compression", {"input_mb": input_mb, "rows": rows})
    for r in rows:
        print(f"  {r['split']}: raw {r['raw_mb']:.1f} MB ({r['x_input']:.0f}x input) "
              f"-> paper {r['int8_zlib_mb']:.2f} MB (-{100*r['int8_zlib_reduction']:.1f}%) "
              f"| delta {r['delta_mb']:.2f} MB (-{100*r['delta_reduction']:.1f}%)")
    mean_red = sum(r["int8_zlib_reduction"] for r in rows) / len(rows)
    mean_red_d = sum(r["delta_reduction"] for r in rows) / len(rows)
    return csv_line("fig3_compression", 1e6 * sum(r["int8_zlib_s"] for r in rows) / len(rows),
                    f"paper_reduction={mean_red:.3f};delta_reduction={mean_red_d:.3f}")


if __name__ == "__main__":
    print(run())

"""Paper Fig. 3 + codec hot path: payload sizes and encode/decode wall time.

Runs the REAL Swin-T head on a realistic video frame and the real codec,
twice per split payload:

  * LEGACY per-tensor loop (``fused=False``): one quant launch, one
    device->host transfer and one zlib call per boundary tensor, host-side
    delta filter -- the paper-faithful but serial baseline.
  * FUSED single-launch path (default): every leaf packed into one device
    pass (kernels/codec.py), one transfer, one zlib call.

Reports the paper-faithful pipeline (INT8+zlib) and the beyond-paper
delta-filtered variant side by side, verifies the two paths decode to
BIT-IDENTICAL tensors, and asserts the fused encode is >= 2x faster.
Rows land in results/bench_compression.json (the codec perf trajectory;
fast mode writes bench_compression_fast.json so the harness never
overwrites the full-size numbers).

Attribution note for off-TPU runs: the legacy loop pays per-leaf
interpret-mode Pallas dispatch (its real shipped cost on this host),
while the fused path runs one native-XLA executable -- so the measured
gap bundles the launch-count reduction WITH the per-launch overhead it
amortizes.  That is the point of the design (on TPU the per-launch
dispatch + per-leaf transfer play the same role), but don't read the
ratio as pure kernel-fusion gain.

    PYTHONPATH=src python -m benchmarks.bench_compression          # full size
    PYTHONPATH=src python -m benchmarks.bench_compression --fast   # reduced
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line, save
from repro.configs.swin_t_detection import CONFIG, reduced
from repro.core.compression import ActivationCodec
from repro.core.splitting import SwinSplitPlan, SERVER_ONLY, UE_ONLY
from repro.data.video import SyntheticVideo, VideoConfig
from repro.models import swin as SW

MODES = ("int8_zlib", "int8_delta_zlib")


def _best_of(fn, reps: int) -> float:
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())   # async dispatch must not stop the clock
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _bit_identical(a_tree, b_tree) -> bool:
    return all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(a_tree),
                               jax.tree.leaves(b_tree)))


def run(fast: bool = False, reps: int = 3):
    cfg = reduced() if fast else CONFIG
    params = SW.init(cfg, jax.random.PRNGKey(0))
    video = SyntheticVideo(VideoConfig(h=cfg.img_h, w=cfg.img_w, seed=0))
    img = jnp.asarray(video.frame(0)[0])[None]
    plan = SwinSplitPlan(cfg, params)

    rows = []
    input_mb = cfg.img_h * cfg.img_w * 3 / 2 ** 20
    for opt in plan.options:
        if opt in (UE_ONLY, SERVER_ONLY):
            continue
        payload, _ = plan.head(img, opt)
        row = {"split": opt}
        for mode in MODES:
            legacy = ActivationCodec(mode=mode, fused=False)
            fused = ActivationCodec(mode=mode)
            # warm both paths (jit compile / zlib dictionaries are not
            # what we are measuring), then verify interchangeability
            cl, cf = legacy.compress(payload), fused.compress(payload)
            out_l, out_f = legacy.decompress(cl), fused.decompress(cf)
            identical = _bit_identical(out_l, out_f)
            row.setdefault("raw_mb", cl.raw_bytes / 2 ** 20)
            row.setdefault("x_input", cl.raw_bytes / 2 ** 20 / input_mb)
            row[mode] = {
                "legacy_mb": cl.compressed_bytes / 2 ** 20,
                "fused_mb": cf.compressed_bytes / 2 ** 20,
                "reduction": 1 - cf.ratio,
                "enc_legacy_s": _best_of(lambda: legacy.compress(payload), reps),
                "enc_fused_s": _best_of(lambda: fused.compress(payload), reps),
                "dec_legacy_s": _best_of(lambda: legacy.decompress(cl), reps),
                "dec_fused_s": _best_of(lambda: fused.decompress(cf), reps),
                "bit_identical": identical,
            }
            assert identical, f"{opt}/{mode}: fused and legacy decode diverge"
        rows.append(row)
        for mode in MODES:
            m = row[mode]
            print(f"  {opt:7s} {mode:16s} raw {row['raw_mb']:6.2f} MB "
                  f"({row['x_input']:4.1f}x input) -> {m['fused_mb']:5.2f} MB "
                  f"(-{100 * m['reduction']:4.1f}%) | enc "
                  f"{1e3 * m['enc_legacy_s']:7.1f} -> {1e3 * m['enc_fused_s']:6.1f} ms "
                  f"({m['enc_legacy_s'] / m['enc_fused_s']:4.1f}x) | dec "
                  f"{1e3 * m['dec_legacy_s']:6.1f} -> {1e3 * m['dec_fused_s']:5.1f} ms "
                  f"({m['dec_legacy_s'] / m['dec_fused_s']:4.1f}x)")

    enc_speedups = [r[m]["enc_legacy_s"] / r[m]["enc_fused_s"]
                    for r in rows for m in MODES]
    dec_speedups = [r[m]["dec_legacy_s"] / r[m]["dec_fused_s"]
                    for r in rows for m in MODES]
    summary = {
        "input_mb": input_mb,
        "fast": fast,
        "note": ("off-TPU the legacy baseline pays per-leaf interpret-mode "
                 "dispatch; the ratio bundles launch-count reduction with "
                 "the per-launch overhead it amortizes (module docstring)"),
        "rows": rows,
        "enc_speedup_min": min(enc_speedups),
        "enc_speedup_max": max(enc_speedups),
        "dec_speedup_min": min(dec_speedups),
        "dec_speedup_max": max(dec_speedups),
        "mean_reduction_int8_zlib": float(np.mean(
            [r["int8_zlib"]["reduction"] for r in rows])),
        "mean_reduction_delta": float(np.mean(
            [r["int8_delta_zlib"]["reduction"] for r in rows])),
    }
    save("bench_compression_fast" if fast else "bench_compression", summary)
    print(f"  fused encode speedup {min(enc_speedups):.1f}x..{max(enc_speedups):.1f}x, "
          f"decode {min(dec_speedups):.1f}x..{max(dec_speedups):.1f}x "
          f"(bit-identical decompressed tensors)")
    # the >=2x bar is the full-size acceptance check; fast mode (tiny
    # payloads, harness sanity run) only warns so a noisy host can't
    # knock out the rest of the benchmark registry
    if fast:
        if min(enc_speedups) < 2.0:
            print(f"  WARNING: fast-mode encode speedup "
                  f"{min(enc_speedups):.2f}x below the 2x full-size bar")
    else:
        assert min(enc_speedups) >= 2.0, \
            "single-launch fused encode must be >= 2x the per-tensor loop"
    mean_enc_us = 1e6 * np.mean([r[m]["enc_fused_s"] for r in rows for m in MODES])
    return csv_line(
        "fig3_compression", mean_enc_us,
        f"paper_reduction={summary['mean_reduction_int8_zlib']:.3f};"
        f"delta_reduction={summary['mean_reduction_delta']:.3f};"
        f"enc_speedup={min(enc_speedups):.1f}x..{max(enc_speedups):.1f}x")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced model/frame (quick sanity run)")
    args = ap.parse_args()
    print(run(fast=args.fast))

"""Shared benchmark plumbing: every bench returns rows and a one-line CSV
summary ``name,us_per_call,derived``; results land in results/*.json."""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), os.pardir, "results")


def save(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def timed(fn: Callable[[], Any]):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def csv_line(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.0f},{derived}"

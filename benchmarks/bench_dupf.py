"""Paper Fig. 8: E2E delay trace, Edge AI over dUPF vs Cloud AI over cUPF
(mean + std; dUPF must win on both)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, save
from repro.configs.swin_t_detection import CONFIG
from repro.core.calibration import PAPER, calibrate
from repro.core.channel import INTERFERENCE_LEVELS, cupf_path, dupf_path
from repro.core.compression import ActivationCodec
from repro.core.pipeline import SplitInferencePipeline
from repro.core.splitting import SwinSplitPlan


def run(n_frames: int = 200):
    system = calibrate()
    plan = SwinSplitPlan(CONFIG, params=None)
    rng = np.random.default_rng(0)
    trace = rng.choice(INTERFERENCE_LEVELS, size=n_frames).tolist()
    out = {}
    for path in (dupf_path(), cupf_path()):
        pipe = SplitInferencePipeline(plan=plan, system=system,
                                      codec=ActivationCodec(),
                                      controller=None, path=path,
                                      execute_model=False, seed=4)
        logs = pipe.run_trace([None] * n_frames, trace, option="split2")
        d = np.asarray([l.delay_s for l in logs]) * 1e3
        out[path.name] = {"mean_ms": float(d.mean()), "std_ms": float(d.std()),
                          "trace_ms": d.tolist()}
        print(f"  {path.name}: mean={d.mean():7.1f} ms std={d.std():6.1f} ms")
    save("bench_dupf", {k: {kk: vv for kk, vv in v.items() if kk != "trace_ms"}
                        for k, v in out.items()})
    gain = out["cUPF"]["mean_ms"] - out["dUPF"]["mean_ms"]
    paper_gain = PAPER["cupf_ms"][0] - PAPER["dupf_ms"][0]
    print(f"  dUPF gain: {gain:.0f} ms mean (paper: {paper_gain:.0f} ms); "
          f"std {out['dUPF']['std_ms']:.0f} vs {out['cUPF']['std_ms']:.0f} "
          f"(paper: {PAPER['dupf_ms'][1]:.0f} vs {PAPER['cupf_ms'][1]:.0f})")
    ok = (out["dUPF"]["mean_ms"] < out["cUPF"]["mean_ms"]
          and out["dUPF"]["std_ms"] < out["cUPF"]["std_ms"])
    return csv_line("fig8_dupf", 0,
                    f"gain_ms={gain:.0f};dupf_wins_mean_and_std={ok}")


if __name__ == "__main__":
    print(run())

"""Correlated multi-cell chaos: simultaneous site faults vs staggered
faults of equal marginal rate, plus the batched park/adopt scale anchor.

Two claims are measured, both on the two-cell A3 mobility topology:

  * **Correlation is strictly worse than rate.**  One weather front
    (3 s link blackout per cell) is injected twice with identical seeds:
    once with ``front_offset_s = 0`` (both cells fault in the SAME
    window -- correlated) and once with a 10 s offset (same per-cell
    outage duration, windows disjoint -- the independent baseline of
    equal marginal rate).  Under the staggered front A3 evacuates the
    dying cell into its healthy neighbor, so frames keep completing;
    under the correlated front both RSRP maps sink together, A3 sees no
    better neighbor, and the fleet is trapped.  Correlated availability
    must be strictly worse overall and no better in any cell.

  * **Batched park/adopt holds at scale.**  A vectorized chaos drain
    (mass blackouts parking/adopting thousands of flows through the
    mask-based ``migrate_ues`` / ``adopt_batch`` path) must cost no more
    than 1.5x the chaos-free drain of the same flow set -- the chaos
    plane is an array epilogue, not a per-UE python loop.

The correlated scenario is also run through BOTH engines and asserted
field-exact, so the CI fast sweep exercises a vectorized-engine chaos
run end to end.

Acceptance anchors (asserted, persisted to results/bench_chaos_corr.json):
  * chaos-free availability is 1.0 at this operating point,
  * correlated overall availability < staggered, same seeds,
  * per-cell: correlated <= staggered everywhere, strictly worse
    somewhere,
  * the staggered front triggers more A3 evacuations than the
    correlated one,
  * vectorized engine matches python field-exact on the correlated run,
  * vectorized chaos drain wall <= 1.5x chaos-free drain.

    PYTHONPATH=src python -m benchmarks.bench_chaos_corr
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_line, save
from repro.configs.swin_t_detection import CONFIG
from repro.core.calibration import calibrate
from repro.core.cell import CellSimulator
from repro.core.chaos import ChaosConfig, ChaosModel, CorrelationSpec
from repro.core.engine_vec import chaos_drain, synthetic_flows
from repro.core.mobility import (MobilityConfig, MobilityModel,
                                 StaticTrajectory, two_cell_sites)
from repro.core.ran import MultiCell, RanCell, RanConfig, RanStream, \
    make_policy
from repro.core.ran_vec import VecRanStream
from repro.core.splitting import SwinSplitPlan

FRONT_S = (4.0, 3.0)      # the front reaches each cell at t0=4s for 3s
STAGGER_S = 10.0          # offset large enough that windows are disjoint


def _front(offset_s: float) -> ChaosModel:
    return ChaosModel(ChaosConfig(correlation=CorrelationSpec(
        weather_front=(FRONT_S,), front_offset_s=offset_s)))


def _sim(system, plan, chaos, *, engine, n_ues, seed, budget_s):
    sites = two_cell_sites(400.0)
    traj = [StaticTrajectory(150.0, 0.0) if u % 2 == 0
            else StaticTrajectory(250.0, 0.0) for u in range(n_ues)]
    mob = MobilityModel(sites, traj,
                        MobilityConfig(a3_ttt_s=0.4, relocation_gap_s=0.05))
    return CellSimulator(
        plan=plan, system=system, n_ues=n_ues, seed=seed,
        execute_model=False, frame_budget_s=budget_s,
        ran=MultiCell([RanCell(policy=make_policy("edf"),
                               cfg=RanConfig(tti_s=0.005))
                       for _ in sites]),
        engine=engine, mobility=mob, chaos=chaos)


def _drain_wall(n_flows, n_ues, blackouts, seed):
    stream = VecRanStream(RanCell(policy=make_policy("edf"), cfg=RanConfig()),
                          n_ues=n_ues)
    flows = synthetic_flows(n_flows, seed=seed, n_ues=n_ues)
    rng = np.random.default_rng(np.random.SeedSequence(seed + 1))
    t0 = time.perf_counter()
    done = chaos_drain(stream, flows, rng, blackouts=blackouts,
                       batch_enqueue=True)
    wall = time.perf_counter() - t0
    assert len(done) == n_flows
    return wall


def run(fast: bool = False, level: float = -40.0, n_ues: int = 4,
        budget_s: float = 4.0, seed: int = 7):
    system = calibrate()
    plan = SwinSplitPlan(CONFIG, params=None)
    option, fps, n_frames, inflight = "server_only", 1.0, 20, 2
    trace = np.full((n_frames, n_ues), float(level))

    table = {"config": {"option": option, "level_db": level, "n_ues": n_ues,
                        "budget_s": budget_s, "n_frames": n_frames,
                        "fps": fps, "inflight": inflight, "fast": fast,
                        "front": FRONT_S, "stagger_s": STAGGER_S}}

    def go(chaos, engine="python"):
        return _sim(system, plan, chaos, engine=engine, n_ues=n_ues,
                    seed=seed, budget_s=budget_s).run_stream(
            trace, option=option, fps=fps, jitter_s=0.05, inflight=inflight)

    # -- correlated vs staggered front, identical seeds ----------------------
    base = go(None)
    corr = go(_front(0.0))
    stag = go(_front(STAGGER_S))
    cells = sorted(corr.stats.cell_stats) or [0, 1]
    rows = {}
    for name, res in (("chaos_free", base), ("correlated", corr),
                      ("staggered", stag)):
        st = res.stats
        rows[name] = {
            "availability": st.availability,
            "cell_availability": {c: st.cell_availability(c) for c in cells},
            "n_handovers": st.n_handovers,
            "n_outages": st.n_outages,
            "cell_stats": {c: dict(v) for c, v in st.cell_stats.items()},
        }
        table[name] = rows[name]
        print(f"  {name:>11s} | avail {st.availability:.3f} "
              f"per-cell {[round(st.cell_availability(c), 3) for c in cells]}"
              f" handovers {st.n_handovers}")

    # -- vectorized engine replays the correlated scenario field-exact -------
    corr_vec = go(_front(0.0), engine="vectorized")
    paired = (len(corr.logs) == len(corr_vec.logs)
              and all(a == b for a, b in zip(corr.logs, corr_vec.logs))
              and corr.stats.cell_stats == corr_vec.stats.cell_stats)

    # -- batched park/adopt at scale: chaos drain vs chaos-free drain --------
    n_flows = 1_000 if fast else 10_000
    d_ues = 100 if fast else 500
    blk = [(0.03, 0.12, list(range(0, d_ues, 3))),
           (0.08, 0.20, list(range(1, d_ues, 7)))]
    _drain_wall(min(n_flows, 1_000), d_ues, [], seed)        # warmup
    _drain_wall(min(n_flows, 1_000), d_ues, blk, seed)
    wall_free = _drain_wall(n_flows, d_ues, [], seed)
    wall_chaos = _drain_wall(n_flows, d_ues, blk, seed)
    ratio = wall_chaos / wall_free
    table["scale"] = {"n_flows": n_flows, "n_ues": d_ues,
                      "n_blackouts": len(blk),
                      "wall_free_s": wall_free, "wall_chaos_s": wall_chaos,
                      "ratio": ratio}
    print(f"  drain {n_flows} flows | free {wall_free:.2f}s "
          f"chaos {wall_chaos:.2f}s ratio {ratio:.3f}")

    # -- acceptance anchors --------------------------------------------------
    av = {k: rows[k]["availability"] for k in rows}
    pc_corr = rows["correlated"]["cell_availability"]
    pc_stag = rows["staggered"]["cell_availability"]
    base_ok = av["chaos_free"] == 1.0
    overall_ok = av["correlated"] < av["staggered"]
    cells_ok = all(pc_corr[c] <= pc_stag[c] for c in cells)
    strict_ok = any(pc_corr[c] < pc_stag[c] for c in cells)
    evac_ok = (rows["staggered"]["n_handovers"]
               > rows["correlated"]["n_handovers"])
    ratio_ok = ratio <= 1.5
    table["acceptance"] = {
        "chaos_free_availability_is_one": base_ok,
        "correlated_strictly_worse_overall": overall_ok,
        "correlated_no_better_in_any_cell": cells_ok,
        "correlated_strictly_worse_in_some_cell": strict_ok,
        "staggered_front_evacuates_more": evac_ok,
        "vectorized_matches_python_field_exact": bool(paired),
        "chaos_drain_within_1p5x_of_free": ratio_ok,
    }
    assert base_ok, f"chaos-free anchor must be clean: {av['chaos_free']}"
    assert overall_ok, ("correlated site faults must be strictly worse than "
                        f"independent faults of equal marginal rate: {av}")
    assert cells_ok and strict_ok, (
        f"per-cell availability corr {pc_corr} vs stag {pc_stag}")
    assert evac_ok, ("A3 must evacuate more under the staggered front: "
                     f"{rows['staggered']['n_handovers']} vs "
                     f"{rows['correlated']['n_handovers']}")
    assert paired, "vectorized engine must replay correlated chaos exactly"
    assert ratio_ok, (f"batched park/adopt too slow: chaos {wall_chaos:.2f}s"
                      f" > 1.5x free {wall_free:.2f}s")

    save("bench_chaos_corr_fast" if fast else "bench_chaos_corr", table)
    return csv_line(
        "chaos_correlated", 0,
        f"avail_corr={av['correlated']:.3f}<stag={av['staggered']:.3f};"
        f"evac={rows['staggered']['n_handovers']}>"
        f"{rows['correlated']['n_handovers']};drain_ratio={ratio:.2f}")


if __name__ == "__main__":
    print(run())

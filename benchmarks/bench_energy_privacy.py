"""Paper Fig. 5: UE total energy (bars) + privacy leakage dCor (line) per
split.  Energy from the calibrated accounting pipeline; privacy from REAL
activations (reduced-resolution Swin over 24 video frames -- dCor is a
correlation structure metric, stable across resolution)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line, save
from repro.configs.swin_t_detection import CONFIG, reduced
from repro.core.calibration import PAPER, calibrate
from repro.core.compression import ActivationCodec
from repro.core.pipeline import SplitInferencePipeline
from repro.core.privacy import payload_privacy
from repro.core.splitting import SwinSplitPlan, SERVER_ONLY, UE_ONLY
from repro.data.video import SyntheticVideo, VideoConfig
from repro.models import swin as SW


def privacy_profile(n_frames: int = 24):
    cfg = reduced()
    params = SW.init(cfg, jax.random.PRNGKey(0))
    video = SyntheticVideo(VideoConfig(h=cfg.img_h, w=cfg.img_w, seed=1))
    imgs = jnp.asarray(np.stack([video.frame(t)[0] for t in range(n_frames)]))
    plan = SwinSplitPlan(cfg, params)
    prof = {UE_ONLY: 0.0}
    for opt in plan.options:
        if opt == UE_ONLY:
            continue
        if opt == SERVER_ONLY:
            prof[opt] = 1.0
            continue
        payload, _ = plan.head(imgs, opt)
        prof[opt] = payload_privacy(imgs, payload)
    return prof


def run():
    system = calibrate()
    plan = SwinSplitPlan(CONFIG, params=None)
    pipe = SplitInferencePipeline(plan=plan, system=system,
                                  codec=ActivationCodec(), controller=None,
                                  execute_model=False, seed=0)
    prof = privacy_profile()
    rows = []
    for opt in plan.options:
        logs = pipe.run_trace([None] * 20, [-20] * 20, opt)
        wh = float(np.mean([l.energy_j for l in logs]) / 3600)
        rows.append({"split": opt, "energy_wh": wh, "privacy": prof[opt]})
        print(f"  {opt:12s} energy={wh:.5f} Wh/frame privacy={prof[opt]:.3f}")
    save("bench_energy_privacy", rows)

    # paper validation: monotone privacy decline split1..4; endpoints 0/1;
    # energy falls with offload depth
    ps = [r["privacy"] for r in rows if r["split"].startswith("split")]
    monotone = all(a >= b for a, b in zip(ps, ps[1:]))
    e_ue = next(r["energy_wh"] for r in rows if r["split"] == UE_ONLY)
    e_s1 = next(r["energy_wh"] for r in rows if r["split"] == "split1")
    red = 1 - e_s1 / e_ue
    print(f"  split1 energy reduction vs UE-only: {100*red:.1f}% "
          f"(paper: 76.1%); privacy monotone decline: {monotone}")
    return csv_line("fig5_energy_privacy", 0,
                    f"split1_energy_red={red:.3f};privacy_monotone={monotone}")


if __name__ == "__main__":
    print(run())

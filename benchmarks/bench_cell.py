"""Multi-UE cell sweep: UEs x interference x batching on/off.

Accounting-mode cell simulation on the paper-calibrated system: every UE
runs sense -> head -> encode -> uplink per frame and the edge server
serves the tails either sequentially (one launch per UE) or through the
deadline-aware micro-batcher (core/cell.py).  Reports per-frame edge
compute time, mean E2E delay, queueing delay, edge utilization, and batch
occupancy; finishes with an execute-model spot check that batched and
sequential tails produce identical detections.

    PYTHONPATH=src python -m benchmarks.bench_cell
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, save
from repro.configs.swin_t_detection import CONFIG, reduced
from repro.core.calibration import calibrate
from repro.core.cell import CellSimulator, cell_interference_traces
from repro.core.splitting import SwinSplitPlan


def run(n_frames: int = 8, option: str = "split2",
        ue_counts=(32, 64, 128, 256), levels=(-40, -20, -5)):
    system = calibrate()
    plan = SwinSplitPlan(CONFIG, params=None)
    table = {}
    print(f"  {'UEs':>4s} {'dB':>4s} | {'edge s/frame':>24s} | "
          f"{'mean delay':>21s} | {'queue':>7s} {'util':>5s} {'occ':>5s}")
    print(f"  {'':>4s} {'':>4s} | {'seq':>11s} {'batched':>12s} | "
          f"{'seq':>10s} {'batched':>10s} |")
    for n_ues in ue_counts:
        for lvl in levels:
            trace = np.full((n_frames, n_ues), float(lvl))
            kw = dict(plan=plan, system=system, n_ues=n_ues, seed=7,
                      execute_model=False)
            seq = CellSimulator(batching=False, **kw).run(trace, option=option)
            bat = CellSimulator(batching=True, **kw).run(trace, option=option)
            row = {
                "edge_s_per_frame_seq": seq.stats.edge_busy_s / n_frames,
                "edge_s_per_frame_batched": bat.stats.edge_busy_s / n_frames,
                "delay_s_seq": seq.mean_delay_s,
                "delay_s_batched": bat.mean_delay_s,
                "queue_s_batched": bat.stats.mean_queue_s,
                "edge_utilization": bat.stats.edge_utilization,
                "batch_occupancy": bat.stats.mean_batch_occupancy,
            }
            table[f"ues{n_ues}_db{lvl}"] = row
            print(f"  {n_ues:4d} {lvl:4d} | {row['edge_s_per_frame_seq']:10.2f}s"
                  f" {row['edge_s_per_frame_batched']:11.2f}s |"
                  f" {row['delay_s_seq']:9.2f}s {row['delay_s_batched']:9.2f}s |"
                  f" {row['queue_s_batched']:6.2f}s"
                  f" {row['edge_utilization']:5.2f}"
                  f" {row['batch_occupancy']:5.2f}")

    speedups = [r["edge_s_per_frame_seq"] / r["edge_s_per_frame_batched"]
                for r in table.values()]
    assert min(speedups) > 1.0, "batching must reduce edge compute time"
    print(f"  edge-compute speedup from batching: "
          f"{min(speedups):.2f}x .. {max(speedups):.2f}x")

    # mixed per-UE interference + adaptive-free heterogeneous sweep
    n_ues = max(ue_counts)
    trace = cell_interference_traces(n_frames, n_ues, seed=3)
    kw = dict(plan=plan, system=system, n_ues=n_ues, seed=7,
              execute_model=False)
    seq = CellSimulator(batching=False, **kw).run(trace, option=option)
    bat = CellSimulator(batching=True, **kw).run(trace, option=option)
    mixed_speedup = seq.stats.edge_busy_s / bat.stats.edge_busy_s
    table["mixed_trace"] = {"speedup": mixed_speedup,
                            "delay_s_seq": seq.mean_delay_s,
                            "delay_s_batched": bat.mean_delay_s}
    print(f"  mixed {n_ues}-UE trace: edge speedup {mixed_speedup:.2f}x, "
          f"delay {seq.mean_delay_s:.2f}s -> {bat.mean_delay_s:.2f}s")

    # execute-model equivalence: batched and sequential edges produce the
    # same detections (scheduling changes, semantics don't)
    import jax
    cfg = reduced()
    from repro.models import swin as SW
    eplan = SwinSplitPlan(cfg, SW.init(cfg, jax.random.PRNGKey(0)))
    imgs = [jax.random.uniform(jax.random.PRNGKey(i),
                               (1, cfg.img_h, cfg.img_w, 3)) for i in range(4)]
    ekw = dict(plan=eplan, system=system, n_ues=4, seed=0, execute_model=True,
               max_wait_s=30.0)
    lv = np.full((1, 4), -30.0)
    out_b = CellSimulator(batching=True, **ekw).run(
        lv, imgs=imgs, option=option, keep_outputs=True).outputs[0]
    out_s = CellSimulator(batching=False, **ekw).run(
        lv, imgs=imgs, option=option, keep_outputs=True).outputs[0]
    max_err = 0.0
    for i in range(4):
        for lv_b, lv_s in zip(out_b[i], out_s[i]):
            max_err = max(max_err, float(np.max(np.abs(
                np.asarray(lv_b["cls"]) - np.asarray(lv_s["cls"])))))
    identical = max_err < 1e-4
    print(f"  execute-model equivalence: max |cls_batched - cls_seq| = "
          f"{max_err:.2e} (identical detections: {identical})")
    assert identical
    table["equivalence_max_abs_err"] = max_err

    save("bench_cell", table)
    return csv_line("cell_batching", 0,
                    f"speedup={min(speedups):.2f}x..{max(speedups):.2f}x;"
                    f"equiv_err={max_err:.1e}")


if __name__ == "__main__":
    print(run())
